"""Device-resident environment fleet (Anakin-style batched pure-JAX envs).

``BENCH_sebulba.json`` shows the fused actor pipeline's win collapsing as
the batch grows because host env stepping + the per-step action sync
dominate — the Podracer paper's own prescription for that regime is to
put the environments on the accelerator.  ``DeviceEnvFleet`` is that
path: a batch of ``repro.api.DeviceEnv`` environments exposed as three
pure batched functions

    fleet.init(rng)            -> FleetState            ((B, ...) leaves)
    fleet.step(state, actions) -> (FleetState, TimeStep with (B,) fields)
    fleet.observe(state)       -> obs (B, ...)

that compose into ONE donated jit with the agent's ``act`` (Sebulba's
device actor branch, core/sebulba.py) or into Anakin's compiled block —
the interaction loop never touches the host, and the per-step action
sync of the host path disappears entirely.

Scenario mix: the fleet batch is apportioned across a weighted
``ScenarioMix`` portfolio (repro/api/env.py).  Rows are laid out
scenario-blocked *within each of ``shards`` equal blocks*, so slicing the
batch across learner shards (or Anakin devices) gives every shard the
same scenario composition — which also makes replay-ring slots
scenario-pure when the ring capacity aligns (the per-scenario replay
strata; see core/sebulba.py).  ``scenario_ids`` names each row's
scenario; ``FleetStats`` accumulates per-scenario reward/episode counters
on device inside the fused step (drained to host only on trajectory
boundaries).

``HostDeviceEnv`` adapts a single DeviceEnv to the imperative host API
(``reset()/step(a) -> obs, reward, done, info``) by stepping it eagerly —
the bit-exactness reference the jit+vmap fleet is pinned against
(tests/test_device_envs.py), and a way to drive device envs through the
BatchedHostEnv pipeline for A/B comparisons.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.env import (
    ScenarioMix,
    resolve_scenarios,
    scenario_rows,
)
from repro.envs.types import TimeStep

PyTree = Any


class FleetStats(NamedTuple):
    """Per-scenario counters, accumulated ON DEVICE inside the fused step.

    ``running_return`` is the per-row return of the episode in flight;
    completed episodes fold into the (S,) scenario aggregates.  All
    counters are cumulative over the fleet's lifetime, so the host can
    read a consistent snapshot at any boundary without resetting state.
    """

    running_return: jax.Array  # (B,) float32
    reward_sum: jax.Array  # (S,) float32 — all rewards, complete or not
    return_sum: jax.Array  # (S,) float32 — sum of COMPLETED episode returns
    episodes: jax.Array  # (S,) float32 — completed episode count


class DeviceEnvFleet:
    """A batch of device envs (one scenario portfolio) as pure functions.

    Stateless like the envs it wraps: all mutable state lives in the
    ``FleetState`` pytree (a tuple of per-scenario stacked env states), so
    one fleet instance serves every actor thread.  ``shards`` interleaves
    the scenario layout so any split of the batch into ``shards`` equal
    blocks preserves the scenario mix per block (batch must divide by
    ``shards``).
    """

    def __init__(self, env_or_scenarios, num_envs: int, shards: int = 1):
        self.scenarios: tuple[ScenarioMix, ...] = resolve_scenarios(
            env_or_scenarios
        )
        if num_envs % shards:
            raise ValueError(
                f"fleet batch {num_envs} must divide across {shards} shards"
            )
        self.num_envs = num_envs
        self.shards = shards
        self.envs = tuple(s.env_factory() for s in self.scenarios)
        self.num_actions = self.envs[0].num_actions
        self.obs_shape = tuple(self.envs[0].obs_shape)
        self.num_scenarios = len(self.scenarios)
        # rows per scenario within ONE shard block, replicated over blocks
        per_shard = scenario_rows(self.scenarios, num_envs // shards)
        self.rows = tuple(r * shards for r in per_shard)
        block = np.concatenate(
            [np.full(r, i, np.int32) for i, r in enumerate(per_shard)]
        )
        self.scenario_ids = np.tile(block, shards)  # (B,) row -> scenario
        # per-scenario row gather indices: scenario s owns the rows where
        # scenario_ids == s (contiguous within each shard block)
        self._gather = tuple(
            np.flatnonzero(self.scenario_ids == i).astype(np.int32)
            for i in range(self.num_scenarios)
        )

    # ------------------------------------------------------------- pure fns

    def init(self, rng: jax.Array):
        """Per-row keys -> tuple of per-scenario stacked env states."""
        keys = jax.random.split(rng, self.num_envs)
        return tuple(
            jax.vmap(env.init)(keys[jnp.asarray(idx)])
            for env, idx in zip(self.envs, self._gather)
        )

    def observe(self, state) -> jax.Array:
        obs = [
            jax.vmap(env.observe)(s) for env, s in zip(self.envs, state)
        ]
        return self._scatter(obs)

    def step(self, state, actions: jax.Array):
        """Batched step across the portfolio -> (state, TimeStep((B,) ...)).

        Each scenario's sub-batch steps under its own vmapped ``step``;
        the timestep fields scatter back to the fleet row order, so the
        consumer sees one (B,) batch regardless of the mix.
        """
        new_state, steps = [], []
        for env, idx, s in zip(self.envs, self._gather, state):
            ns, ts = jax.vmap(env.step)(s, actions[jnp.asarray(idx)])
            new_state.append(ns)
            steps.append(ts)
        ts = TimeStep(
            obs=self._scatter([t.obs for t in steps]),
            reward=self._scatter([t.reward for t in steps]),
            discount=self._scatter([t.discount for t in steps]),
            first=self._scatter([t.first for t in steps]),
        )
        return tuple(new_state), ts

    def _scatter(self, parts: Sequence[jax.Array]) -> jax.Array:
        """Per-scenario (r_s, ...) stacks -> fleet row order (B, ...)."""
        if self.num_scenarios == 1:
            return parts[0]
        out = jnp.concatenate(parts, axis=0)
        order = np.concatenate(self._gather)
        inv = np.empty_like(order)
        inv[order] = np.arange(len(order), dtype=np.int32)
        return out[jnp.asarray(inv)]

    # ------------------------------------------------------------ stats

    def init_stats(self) -> FleetStats:
        S = self.num_scenarios
        return FleetStats(
            running_return=jnp.zeros((self.num_envs,), jnp.float32),
            reward_sum=jnp.zeros((S,), jnp.float32),
            return_sum=jnp.zeros((S,), jnp.float32),
            episodes=jnp.zeros((S,), jnp.float32),
        )

    def update_stats(self, stats: FleetStats, ts: TimeStep) -> FleetStats:
        """Fold one batched step into the per-scenario counters (traced
        inside the fused actor step — pure, no host sync)."""
        seg = jnp.asarray(self.scenario_ids)
        S = self.num_scenarios
        done = (ts.discount == 0.0).astype(jnp.float32)
        running = stats.running_return + ts.reward
        return FleetStats(
            running_return=running * (1.0 - done),
            reward_sum=stats.reward_sum
            + jax.ops.segment_sum(ts.reward, seg, S),
            return_sum=stats.return_sum
            + jax.ops.segment_sum(running * done, seg, S),
            episodes=stats.episodes + jax.ops.segment_sum(done, seg, S),
        )

    def stats_summary(self, stats: FleetStats) -> dict:
        """Host-side snapshot -> {scenario: counters} (syncs on ``stats``;
        call on boundaries only)."""
        reward = np.asarray(stats.reward_sum)
        returns = np.asarray(stats.return_sum)
        episodes = np.asarray(stats.episodes)
        out = {}
        for i, s in enumerate(self.scenarios):
            n = float(episodes[i])
            out[s.name] = {
                "weight": s.weight,
                "rows": self.rows[i],
                "episodes": int(n),
                "reward_sum": float(reward[i]),
                "return_sum": float(returns[i]),
                "mean_return": float(returns[i] / n) if n else float("nan"),
            }
        return out


class HostDeviceEnv:
    """A single DeviceEnv behind the imperative host API (eager stepping).

    Device envs auto-reset inside ``step`` (the returned obs already opens
    the next episode), so after the first call ``reset()`` is a no-op
    returning the current observation — exactly what ``BatchedHostEnv``'s
    done-handling expects, which keeps a pool of these bit-aligned with a
    ``DeviceEnvFleet`` over the same seeds (the parity suite's harness).
    """

    def __init__(self, env, seed: int = 0):
        self.env = env
        self.num_actions = env.num_actions
        self.obs_shape = tuple(env.obs_shape)
        self._rng = jax.random.key(seed)
        self._state = None

    def reset(self) -> np.ndarray:
        if self._state is None:
            self._state = self.env.init(self._rng)
        return np.asarray(self.env.observe(self._state))

    def step(self, action):
        if self._state is None:
            self._state = self.env.init(self._rng)
        self._state, ts = self.env.step(self._state, jnp.int32(action))
        done = bool(np.asarray(ts.discount) == 0.0)
        return np.asarray(ts.obs), np.float32(ts.reward), done, {}

    def close(self) -> None:  # host-API symmetry; nothing to release
        self._state = None
