"""The paper's "special batched environment".

    "each Python actor-thread interacts with a special batched environment;
     this is exposed to Python as a single environment that takes a batch of
     actions and returns a batch of observations; behind the scenes it steps
     each environment in the batch in parallel using a shared pool of C++
     threads."

Here the shared pool is a ``ThreadPoolExecutor`` (numpy releases the GIL for
array work, and one pool is shared by all actor threads, as in the paper).
Episodes auto-reset so actors never block on episode boundaries; ``done``
flags mark boundaries for the learner's discount mask.

The shared pool is reference-counted: every ``BatchedHostEnv`` riding on it
holds a reference, and ``close()`` releases it, shutting the pool down when
the last env lets go — so env-pool threads no longer outlive ``fit()``.
``shared_pool(workers=N)`` grows the pool when a later caller asks for more
workers than the first caller pinned (the executor spawns threads lazily up
to its ceiling, so raising the ceiling on a live pool is safe).
"""

from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Callable

import numpy as np


class BatchedHostEnv:
    _shared_pool: ThreadPoolExecutor | None = None
    _shared_refs: int = 0
    _shared_lock = threading.Lock()

    @classmethod
    def shared_pool(cls, workers: int = 8) -> ThreadPoolExecutor:
        """The process-wide env-stepping pool, grown to ``workers`` if a
        later caller needs more than the first caller asked for."""
        with cls._shared_lock:
            if cls._shared_pool is None:
                cls._shared_pool = ThreadPoolExecutor(
                    max_workers=workers, thread_name_prefix="env-pool"
                )
            elif workers > cls._shared_pool._max_workers:
                # ThreadPoolExecutor spawns threads lazily up to
                # _max_workers; raising the ceiling in place honors the
                # larger request without invalidating live references.
                cls._shared_pool._max_workers = workers
            return cls._shared_pool

    @classmethod
    def _release_shared(cls) -> None:
        with cls._shared_lock:
            cls._shared_refs -= 1
            if cls._shared_refs <= 0 and cls._shared_pool is not None:
                cls._shared_pool.shutdown(wait=True)
                cls._shared_pool = None
                cls._shared_refs = 0

    def __init__(
        self,
        env_factory: Callable[[int], object],
        num_envs: int,
        pool: ThreadPoolExecutor | None = None,
    ):
        self.envs = [env_factory(i) for i in range(num_envs)]
        self.num_envs = num_envs
        self.num_actions = self.envs[0].num_actions
        self.obs_shape = self.envs[0].obs_shape
        self._owns_shared = pool is None
        if self._owns_shared:
            # a batch of N envs wants N-wide stepping; grow the shared
            # pool instead of letting the first caller pin its size
            self.pool = self.shared_pool(workers=max(8, num_envs))
            with type(self)._shared_lock:
                type(self)._shared_refs += 1
        else:
            self.pool = pool
        self._closed = False

    def close(self) -> None:
        """Release this env's pool reference (shutting the shared pool down
        with the last reference) and close closable member envs."""
        if self._closed:
            return
        self._closed = True
        for env in self.envs:
            if hasattr(env, "close"):
                env.close()
        if self._owns_shared:
            self._release_shared()

    def reset(self) -> np.ndarray:
        return np.stack(
            list(self.pool.map(lambda env: env.reset(), self.envs))
        )

    def _step_one(self, i: int, action: int):
        env = self.envs[i]
        obs, reward, done, _ = env.step(int(action))
        if done:
            obs = env.reset()
        return obs, reward, done

    def step(self, actions: np.ndarray):
        """actions (N,) -> obs (N, ...), rewards (N,), dones (N,)."""
        results = list(
            self.pool.map(self._step_one, range(self.num_envs), actions)
        )
        obs = np.stack([r[0] for r in results])
        rewards = np.array([r[1] for r in results], np.float32)
        dones = np.array([r[2] for r in results], bool)
        return obs, rewards, dones
