"""The paper's "special batched environment".

    "each Python actor-thread interacts with a special batched environment;
     this is exposed to Python as a single environment that takes a batch of
     actions and returns a batch of observations; behind the scenes it steps
     each environment in the batch in parallel using a shared pool of C++
     threads."

Here the shared pool is a ``ThreadPoolExecutor`` (numpy releases the GIL for
array work, and one pool is shared by all actor threads, as in the paper).
Episodes auto-reset so actors never block on episode boundaries; ``done``
flags mark boundaries for the learner's discount mask.
"""

from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Callable

import numpy as np


class BatchedHostEnv:
    _shared_pool: ThreadPoolExecutor | None = None

    @classmethod
    def shared_pool(cls, workers: int = 8) -> ThreadPoolExecutor:
        if cls._shared_pool is None:
            cls._shared_pool = ThreadPoolExecutor(
                max_workers=workers, thread_name_prefix="env-pool"
            )
        return cls._shared_pool

    def __init__(
        self,
        env_factory: Callable[[int], object],
        num_envs: int,
        pool: ThreadPoolExecutor | None = None,
    ):
        self.envs = [env_factory(i) for i in range(num_envs)]
        self.num_envs = num_envs
        self.num_actions = self.envs[0].num_actions
        self.obs_shape = self.envs[0].obs_shape
        self.pool = pool or self.shared_pool()

    def reset(self) -> np.ndarray:
        return np.stack([env.reset() for env in self.envs])

    def _step_one(self, i: int, action: int):
        env = self.envs[i]
        obs, reward, done, _ = env.step(int(action))
        if done:
            obs = env.reset()
        return obs, reward, done

    def step(self, actions: np.ndarray):
        """actions (N,) -> obs (N, ...), rewards (N,), dones (N,)."""
        results = list(
            self.pool.map(self._step_one, range(self.num_envs), actions)
        )
        obs = np.stack([r[0] for r in results])
        rewards = np.array([r[1] for r in results], np.float32)
        dones = np.array([r[2] for r in results], bool)
        return obs, rewards, dones
