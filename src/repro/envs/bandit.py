"""Contextual bandit — the smallest pure-JAX Anakin environment (used for
MCTS sanity checks and as the fastest smoke-test env), plus ``HostBandit``,
its host-side (numpy, dm_env-style) twin for Sebulba smoke tests."""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.envs.types import TimeStep


class BanditState(NamedTuple):
    best_arm: jax.Array
    rng: jax.Array


class Bandit:
    def __init__(self, num_arms: int = 4, noise: float = 0.1):
        self.num_actions = num_arms
        self.noise = noise
        self.obs_shape = (num_arms,)
        self.discount = 0.0  # one-step episodes

    def init(self, rng: jax.Array) -> BanditState:
        rng, sub = jax.random.split(rng)
        return BanditState(
            best_arm=jax.random.randint(sub, (), 0, self.num_actions), rng=rng
        )

    def observe(self, s: BanditState) -> jax.Array:
        # context reveals the best arm (a learnable but non-trivial mapping)
        return jax.nn.one_hot(s.best_arm, self.num_actions)

    def step(self, s: BanditState, action: jax.Array):
        rng, k1, k2 = jax.random.split(s.rng, 3)
        reward = jnp.where(action == s.best_arm, 1.0, 0.0)
        reward = reward + self.noise * jax.random.normal(k1)
        new_state = BanditState(
            best_arm=jax.random.randint(k2, (), 0, self.num_actions), rng=rng
        )
        ts = TimeStep(
            obs=self.observe(new_state),
            reward=reward.astype(jnp.float32),
            discount=jnp.float32(0.0),
            first=jnp.bool_(True),
        )
        return new_state, ts


class HostBandit:
    """Host-side contextual bandit with the HostPong step API.

    One-step episodes: the context (a one-hot of the best arm) is shown,
    the agent picks an arm, reward lands, the episode ends and the arm is
    re-drawn.  The cheapest possible Sebulba workload — every millisecond
    not spent here exercises the actor/replay/learner pipeline instead.
    """

    def __init__(self, num_arms: int = 4, noise: float = 0.1, seed: int = 0):
        self.num_actions = num_arms
        self.noise = noise
        self.obs_shape = (num_arms,)
        self._rng = np.random.RandomState(seed)
        self._best = 0

    def _observe(self) -> np.ndarray:
        obs = np.zeros(self.obs_shape, np.float32)
        obs[self._best] = 1.0
        return obs

    def reset(self) -> np.ndarray:
        self._best = int(self._rng.randint(self.num_actions))
        return self._observe()

    def step(self, action: int):
        """-> (obs, reward, done, info); done every step (1-step episodes)."""
        reward = 1.0 if int(action) == self._best else 0.0
        if self.noise:
            reward += self.noise * float(self._rng.randn())
        self._best = int(self._rng.randint(self.num_actions))
        return self._observe(), np.float32(reward), True, {}
