"""Environment interfaces.

Anakin environments are *pure JAX functions* (the paper's requirement):
``init(rng) -> state`` and ``step(state, action) -> (state, TimeStep)``.
Episode termination is signalled by ``discount == 0``; environments
auto-reset inside ``step`` so that the agent-environment loop is a single
unrollable XLA program (no Python between steps).

Host environments (Sebulba) follow a dm_env-like imperative API in numpy.
"""

from __future__ import annotations

from typing import NamedTuple

import jax


class TimeStep(NamedTuple):
    obs: jax.Array
    reward: jax.Array  # float32 scalar
    discount: jax.Array  # float32 scalar; 0.0 = episode ended this step
    first: jax.Array  # bool: this obs starts a new episode
