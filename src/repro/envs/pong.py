"""Pong — the device twin of ``HostPong``, pure JAX (Anakin contract).

Same arcade game as repro/envs/host_env.py: a ball bounces around an
(H x W) board, the agent moves a 3-cell-tolerance paddle on the bottom
row, an episode is a rally of ``max_lives`` balls.  Implemented against
the ``repro.api.DeviceEnv`` contract so the whole interaction loop can be
jitted/vmapped on the accelerator (the fused env+act actor step,
repro/envs/device_env.py).

Bit-exact parity with the host twin (tests/test_device_envs.py) hinges on
the randomness: both twins draw ball spawns from the SAME counter-based
Philox stream (``spawn_ball`` — ``jax.random`` is deterministic and
backend-independent, so the host twin evaluates the identical draw
eagerly on CPU while the device env traces it).  Each spawn consumes one
monotone counter tick per env lifetime; auto-reset (device) and
``reset()`` (host) advance the same counter, so the obs/reward/done
streams stay aligned through episode boundaries.

Semantics mirrored from the (fixed) host twin exactly:

  * a miss with lives remaining respawns the ball only (one spawn draw);
  * the terminal miss keeps the board as the agent saw it die — no
    mid-step respawn — and the auto-reset then rebuilds the full board
    (fresh ball, centred paddle, full lives: one spawn draw), matching
    ``HostPong.step`` returning the true terminal frame and
    ``BatchedHostEnv`` fanning out ``reset()``.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.types import TimeStep


def spawn_ball(key: jax.Array, n, width: int):
    """Ball spawn draw ``n`` of the env whose stream is ``key``:
    -> (ball_x float32 in [1, width-2], vx float32 in {-1, +1}).

    Counter-based so the numpy host twin and the jitted device env consume
    the same stream: draw ``n`` depends only on (key, n), never on how
    many times either side re-traced or batched the call.
    """
    k = jax.random.fold_in(key, n)
    kx, kv = jax.random.split(k)
    ball_x = jax.random.randint(kx, (), 1, width - 1).astype(jnp.float32)
    vx = jnp.where(jax.random.bernoulli(kv), 1.0, -1.0).astype(jnp.float32)
    return ball_x, vx


class PongState(NamedTuple):
    ball_y: jax.Array  # () float32
    ball_x: jax.Array  # () float32
    vy: jax.Array  # () float32
    vx: jax.Array  # () float32
    paddle: jax.Array  # () int32
    lives: jax.Array  # () int32
    key: jax.Array  # env stream key (constant per env lifetime)
    spawn_n: jax.Array  # () int32 — monotone spawn counter (parity seam)


class Pong:
    num_actions = 3  # left / stay / right

    def __init__(self, height: int = 16, width: int = 16, max_lives: int = 3):
        self.h = height
        self.w = width
        self.max_lives = max_lives
        self.obs_shape = (height, width, 1)
        self.discount = 0.99

    def init(self, rng: jax.Array) -> PongState:
        ball_x, vx = spawn_ball(rng, 0, self.w)
        return PongState(
            ball_y=jnp.float32(0.0),
            ball_x=ball_x,
            vy=jnp.float32(1.0),
            vx=vx,
            paddle=jnp.int32(self.w // 2),
            lives=jnp.int32(self.max_lives),
            key=rng,
            spawn_n=jnp.int32(1),
        )

    def observe(self, s: PongState) -> jax.Array:
        obs = jnp.zeros(self.obs_shape, jnp.float32)
        y = jnp.clip(jnp.round(s.ball_y), 0, self.h - 1).astype(jnp.int32)
        x = jnp.clip(jnp.round(s.ball_x), 0, self.w - 1).astype(jnp.int32)
        obs = obs.at[y, x, 0].set(1.0)
        obs = obs.at[self.h - 1, s.paddle, 0].set(1.0)
        return obs

    def step(self, s: PongState, action: jax.Array) -> tuple[PongState, TimeStep]:
        paddle = jnp.clip(s.paddle + (action - 1), 0, self.w - 1).astype(
            jnp.int32
        )
        ball_y = s.ball_y + s.vy
        ball_x = s.ball_x + s.vx
        wall = (ball_x <= 0) | (ball_x >= self.w - 1)
        vx = jnp.where(wall, -s.vx, s.vx)
        ball_x = jnp.clip(ball_x, 0.0, float(self.w - 1))

        at_bottom = ball_y >= self.h - 1
        caught = at_bottom & (jnp.abs(ball_x - paddle) <= 1)
        missed = at_bottom & ~caught
        reward = jnp.where(caught, 1.0, jnp.where(missed, -1.0, 0.0))
        vy = jnp.where(caught, -1.0, jnp.where(ball_y <= 0, 1.0, s.vy))
        ball_y = jnp.where(caught, jnp.float32(self.h - 2), ball_y)
        lives = s.lives - missed.astype(jnp.int32)
        done = lives <= 0

        # one spawn draw serves both branches (they are mutually exclusive):
        # a non-terminal miss respawns the ball, the terminal miss defers
        # the draw to the auto-reset below — matching the host twin, where
        # ``step`` keeps the terminal board intact and ``reset()`` draws.
        fresh_x, fresh_vx = spawn_ball(s.key, s.spawn_n, self.w)
        respawn = missed & ~done
        moved = PongState(
            ball_y=jnp.where(respawn, 0.0, ball_y),
            ball_x=jnp.where(respawn, fresh_x, ball_x),
            vy=jnp.where(respawn, 1.0, vy),
            vx=jnp.where(respawn, fresh_vx, vx),
            paddle=paddle,
            lives=lives,
            key=s.key,
            spawn_n=s.spawn_n + missed.astype(jnp.int32),
        )
        reset = PongState(
            ball_y=jnp.float32(0.0),
            ball_x=fresh_x,
            vy=jnp.float32(1.0),
            vx=fresh_vx,
            paddle=jnp.int32(self.w // 2),
            lives=jnp.int32(self.max_lives),
            key=s.key,
            spawn_n=s.spawn_n + 1,
        )
        new_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), reset, moved
        )
        ts = TimeStep(
            obs=self.observe(new_state),
            reward=reward.astype(jnp.float32),
            discount=jnp.where(done, 0.0, self.discount).astype(jnp.float32),
            first=done,
        )
        return new_state, ts
