"""Parameter containers and initialization utilities.

We deliberately avoid external NN libraries (flax/haiku are not available in
this environment); instead a small ``ParamBuilder`` collects a nested dict of
arrays *and* a parallel tree of logical-axis annotations.  The logical axes
feed the sharding rules in :mod:`repro.sharding`, MaxText-style.

Everything here supports *abstract* instantiation via ``jax.eval_shape`` so
that the multi-pod dry-run can build ShapeDtypeStructs for a 405B parameter
model without ever allocating memory.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterator

import jax
import jax.numpy as jnp

PyTree = Any
Axes = tuple[str | None, ...]


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def normal_init(stddev: float = 0.02) -> Callable[..., jax.Array]:
    def init(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return init


def fan_in_init(scale: float = 1.0) -> Callable[..., jax.Array]:
    """LeCun-normal style init: stddev = scale / sqrt(fan_in).

    fan_in is taken to be the product of all but the last dimension, which is
    correct for the ``(in, out)``-shaped matrices used throughout this code
    base (einsum contractions contract the leading dims).
    """

    def init(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
        fan_in = max(1, math.prod(shape[:-1]))
        stddev = scale / math.sqrt(fan_in)
        return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)

    return init


def zeros_init() -> Callable[..., jax.Array]:
    def init(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
        del key
        return jnp.zeros(shape, dtype)

    return init


def ones_init() -> Callable[..., jax.Array]:
    def init(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
        del key
        return jnp.ones(shape, dtype)

    return init


def constant_init(value: float) -> Callable[..., jax.Array]:
    def init(key: jax.Array, shape: tuple[int, ...], dtype=jnp.float32) -> jax.Array:
        del key
        return jnp.full(shape, value, dtype)

    return init


# ---------------------------------------------------------------------------
# ParamBuilder
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Collects parameters (nested dict) and their logical axis names.

    Usage::

        b = ParamBuilder(rng, dtype=jnp.bfloat16)
        with b.scope("attn"):
            wq = b.param("wq", (d, h, hd), ("embed", "heads", "head_dim"))
        params, axes = b.build()

    ``axes`` mirrors ``params`` structurally, with an ``Axes`` tuple per leaf.
    The builder hands out a fresh fold of the RNG per parameter so that
    parameter values do not depend on creation order of *other* scopes.
    """

    def __init__(self, rng: jax.Array, dtype=jnp.float32):
        self._rng = rng
        self._dtype = dtype
        self._params: dict[str, Any] = {}
        self._axes: dict[str, Any] = {}
        self._path: list[str] = []
        self._counter = 0

    # -- scoping ------------------------------------------------------------

    def scope(self, name: str) -> "_Scope":
        return _Scope(self, name)

    def _subdict(self, root: dict) -> dict:
        d = root
        for p in self._path:
            d = d.setdefault(p, {})
        return d

    # -- parameters ----------------------------------------------------------

    def param(
        self,
        name: str,
        shape: tuple[int, ...],
        axes: Axes,
        init: Callable[..., jax.Array] | None = None,
        dtype=None,
    ) -> jax.Array:
        if len(axes) != len(shape):
            raise ValueError(
                f"param {'/'.join(self._path + [name])}: shape {shape} has "
                f"{len(shape)} dims but axes {axes} has {len(axes)}"
            )
        init = init or fan_in_init()
        dtype = dtype or self._dtype
        # Fold in a deterministic per-parameter key: hash of path + counter.
        key = jax.random.fold_in(self._rng, self._counter)
        self._counter += 1
        value = init(key, shape, dtype)
        self._subdict(self._params)[name] = value
        self._subdict(self._axes)[name] = axes
        return value

    def build(self) -> tuple[dict, dict]:
        return self._params, self._axes


class _Scope:
    def __init__(self, builder: ParamBuilder, name: str):
        self._builder = builder
        self._name = name

    def __enter__(self) -> ParamBuilder:
        self._builder._path.append(self._name)
        return self._builder

    def __exit__(self, *exc) -> None:
        self._builder._path.pop()


# ---------------------------------------------------------------------------
# Tree utilities
# ---------------------------------------------------------------------------


def tree_size(tree: PyTree) -> int:
    """Total number of elements across all leaves (works on SDS too)."""
    leaves = jax.tree.leaves(tree)
    return sum(math.prod(leaf.shape) for leaf in leaves)


def tree_bytes(tree: PyTree) -> int:
    leaves = jax.tree.leaves(tree)
    return sum(math.prod(l.shape) * jnp.dtype(l.dtype).itemsize for l in leaves)


def abstract_init(init_fn: Callable[[jax.Array], PyTree]) -> PyTree:
    """Shape-infer an init function without allocating memory."""
    rng = jax.random.key(0)
    return jax.eval_shape(init_fn, rng)


def tree_paths(tree: PyTree) -> Iterator[tuple[str, Any]]:
    """Yield ('a/b/c', leaf) pairs for a nested-dict pytree."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        name = "/".join(
            p.key if isinstance(p, jax.tree_util.DictKey) else str(p) for p in path
        )
        yield name, leaf


def assert_trees_match(a: PyTree, b: PyTree, msg: str = "") -> None:
    """Structural equality check used by checkpoint restore."""
    sa = jax.tree_util.tree_structure(a)
    sb = jax.tree_util.tree_structure(b)
    if sa != sb:
        raise ValueError(f"tree structure mismatch {msg}: {sa} vs {sb}")


@dataclasses.dataclass
class ParamInfo:
    """Summary of a parameter tree (used by launch/train logging)."""

    count: int
    bytes: int

    @classmethod
    def of(cls, tree: PyTree) -> "ParamInfo":
        return cls(count=tree_size(tree), bytes=tree_bytes(tree))

    def __str__(self) -> str:
        return f"{self.count / 1e6:.1f}M params, {self.bytes / 1e9:.2f} GB"
