"""Sebulba training one agent across a weighted scenario portfolio of
device-resident envs — "as many scenarios as you can imagine as a config,
not a fork" (ROADMAP).

Three Pong difficulties share one policy: the fleet seats each scenario on
a weighted share of the actor batch (largest-remainder apportionment), the
fused env+act step runs the whole portfolio in one donated jit per step,
and per-scenario episode/return counters flow through the unified result
schema (``repro.api.RESULT_KEYS``'s ``scenarios`` entry).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sebulba_scenarios.py --frames 50000
"""

import argparse

import jax

from repro import optim
from repro.agents.impala import ConvActorCritic
from repro.api import ScenarioMix
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import Pong


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=50_000)
    ap.add_argument("--actor-cores", type=int, default=2)
    ap.add_argument("--actor-batch", type=int, default=32)
    ap.add_argument("--trajectory", type=int, default=20)
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject a seeded FaultPlan (random crashes/"
                         "stragglers across the device-env actor fleet) to "
                         "exercise supervision under the scenario mix")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    actor_cores = min(args.actor_cores, max(1, n_dev - 1)) if n_dev > 1 else 1
    learners = max(n_dev - actor_cores, 1)
    actor_batch = -(-args.actor_batch // learners) * learners
    if actor_batch != args.actor_batch:
        print(f"actor batch {args.actor_batch} -> {actor_batch} "
              f"(multiple of {learners} learners)")
    print(f"devices: {n_dev} -> {actor_cores} actor / "
          f"{learners} learner cores")

    # one agent, three difficulties: weights set the share of fleet rows
    # (and so of training frames) each scenario receives
    scenarios = [
        ScenarioMix("sprint", 2.0, lambda: Pong(max_lives=1)),
        ScenarioMix("rally", 1.0, lambda: Pong(max_lives=3)),
        ScenarioMix("marathon", 1.0, lambda: Pong(max_lives=5)),
    ]

    threads_per_core = 2
    fault_plan = None
    chaos_kwargs = {}
    if args.chaos is not None:
        from repro.fault import FaultPlan

        horizon = max(
            20,
            args.frames // (actor_cores * threads_per_core * actor_batch * 2),
        )
        fault_plan = FaultPlan.random(
            args.chaos,
            actors=actor_cores * threads_per_core,
            horizon=horizon,
            crash_rate=2.0 / horizon,
            slow_rate=4.0 / horizon,
        )
        print(f"chaos seed {args.chaos}: {len(fault_plan.events)} "
              "scheduled faults")
        chaos_kwargs = dict(stall_timeout=5.0, restart_backoff=0.1)

    net = ConvActorCritic(Pong.num_actions, channels=(16, 32), blocks=1)
    seb = Sebulba(
        device_env=scenarios,
        network=net,
        optimizer=optim.rmsprop(3e-4, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=actor_cores,
            threads_per_actor_core=threads_per_core,
            actor_batch_size=actor_batch,
            trajectory_length=args.trajectory,
            **chaos_kwargs,
        ),
        fault_plan=fault_plan,
    )
    out = seb.fit(jax.random.key(0), total_frames=args.frames, log_every=25)
    print(
        f"\n{out['frames']:,} frames in {out['seconds']:.1f}s "
        f"-> {out['fps']:,.0f} FPS, {out['updates']} updates, "
        f"mean return {out['mean_return']:.2f}"
    )
    if args.chaos is not None:
        print(
            f"chaos: {out['actor_restarts']} restarts, "
            f"{out['watchdog_stalls']} watchdog stalls, "
            f"{out['actor_quarantined']} quarantined"
        )
    for name, c in out["scenarios"].items():
        print(f"  {name:>9}: weight {c['weight']:.1f}, rows {c['rows']}, "
              f"episodes {c['episodes']:,}, "
              f"mean return {c['mean_return']:.2f}")


if __name__ == "__main__":
    main()
