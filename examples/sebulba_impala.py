"""Sebulba running IMPALA/V-trace on host (CPU) environments — paper Fig. 3.

Run with several placeholder devices to exercise the actor/learner core
split (on a real TPU host the 8 cores appear automatically):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sebulba_impala.py --frames 50000
"""

import argparse

import jax

from repro import optim
from repro.agents.impala import ConvActorCritic
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import BatchedHostEnv, HostPong, Pong


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=50_000)
    ap.add_argument("--actor-cores", type=int, default=2)
    ap.add_argument("--actor-batch", type=int, default=32)
    ap.add_argument("--trajectory", type=int, default=20)
    ap.add_argument("--device-envs", action="store_true",
                    help="step the pure-JAX Pong twin on device (fused "
                         "env+act actor step) instead of the host env pool")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist param_version-stamped checkpoints here "
                         "(the runner owns persistence — see repro.api)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint every N learner updates")
    ap.add_argument("--restore-from", default=None,
                    help="warm-start params from a checkpoint file or dir")
    ap.add_argument("--chaos", type=int, default=None, metavar="SEED",
                    help="inject a seeded FaultPlan (random crashes/hangs/"
                         "stragglers across the actor fleet) to exercise "
                         "supervision: restarts, watchdog, quarantine. Same "
                         "seed, same schedule.")
    ap.add_argument("--hosts", type=int, default=1, metavar="N",
                    help="run as one host of an N-host elastic fleet "
                         "(N-1 simulated peers renew leases in a shared "
                         "registry dir; with --chaos, seeded host_crash/"
                         "host_rejoin events hit the peers mid-run and the "
                         "learner reshards on each epoch bump)")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    actor_cores = min(args.actor_cores, max(1, n_dev - 1)) if n_dev > 1 else 1
    learners = max(n_dev - actor_cores, 1)
    # the batch shards across learner cores; round up to the next multiple
    # (a 6-learner split would otherwise reject the power-of-two default)
    actor_batch = -(-args.actor_batch // learners) * learners
    if actor_batch != args.actor_batch:
        print(f"actor batch {args.actor_batch} -> {actor_batch} "
              f"(multiple of {learners} learners)")
    print(f"devices: {n_dev} -> {actor_cores} actor / "
          f"{learners} learner cores")

    net = ConvActorCritic(HostPong.num_actions, channels=(16, 32), blocks=1)
    env_kwargs = (
        {"device_env": Pong}
        if args.device_envs
        else {
            "env_factory": lambda seed: HostPong(seed=seed),
            "make_batched_env": lambda f, n: BatchedHostEnv(f, n),
        }
    )
    threads_per_core = 2
    peer_ids = tuple(f"peer{i}" for i in range(args.hosts - 1))
    fault_plan = None
    chaos_kwargs = {}
    if args.chaos is not None:
        from repro.fault import FaultPlan

        # per-slot steps ~ frames / (slots * batch); schedule over the
        # first half so recoveries happen while there is run left to show
        horizon = max(
            20,
            args.frames // (actor_cores * threads_per_core * actor_batch * 2),
        )
        fault_plan = FaultPlan.random(
            args.chaos,
            actors=actor_cores * threads_per_core,
            horizon=horizon,
            crash_rate=2.0 / horizon,   # ~2 crashes per slot
            hang_rate=0.5 / horizon,    # ~1 hang across a 2-slot fleet
            slow_rate=4.0 / horizon,
            # host chaos (the elastic tier): expect ~1 loss per peer over
            # the window, rejoining a quarter-window later.  Host steps
            # count LEARNER updates, which run on a comparable scale.
            peer_hosts=peer_ids,
            host_crash_rate=3.0 / horizon,
            host_rejoin_after=max(2, horizon // 4),
        )
        print(f"chaos seed {args.chaos}: {len(fault_plan.events)} "
              "scheduled faults")
        # a tight (but compile-safe: startup is grace-period exempt) stall
        # budget so injected hangs are caught within the demo run
        chaos_kwargs = dict(stall_timeout=5.0, restart_backoff=0.1)
    cluster = None
    if args.hosts > 1:
        import tempfile

        from repro.distributed import HostSupervisor

        registry_dir = tempfile.mkdtemp(prefix="sebulba_registry_")
        cluster = HostSupervisor(
            registry_dir, "host0", ttl=0.3, peers=peer_ids,
            fault_plan=fault_plan, checkpoint_dir=args.checkpoint_dir,
        )
        print(f"elastic fleet: host0 + {len(peer_ids)} peers, "
              f"registry {registry_dir}")
    seb = Sebulba(
        network=net,
        optimizer=optim.rmsprop(3e-4, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=actor_cores,
            threads_per_actor_core=threads_per_core,
            actor_batch_size=actor_batch,
            trajectory_length=args.trajectory,
            **chaos_kwargs,
        ),
        fault_plan=fault_plan,
        cluster=cluster,
        **env_kwargs,
    )
    out = seb.fit(jax.random.key(0), total_frames=args.frames, log_every=25,
                  checkpoint_dir=args.checkpoint_dir,
                  checkpoint_every=args.checkpoint_every,
                  restore_from=args.restore_from)
    print(
        f"\n{out['frames']:,} frames in {out['seconds']:.1f}s "
        f"-> {out['fps']:,.0f} FPS, {out['updates']} updates, "
        f"mean return {out['mean_return']:.2f}, "
        f"{out['checkpoints_saved']} checkpoints"
    )
    if args.chaos is not None:
        print(
            f"chaos: {out['actor_restarts']} restarts, "
            f"{out['watchdog_stalls']} watchdog stalls, "
            f"{out['actor_quarantined']} quarantined"
        )
    if args.hosts > 1:
        print(
            f"hosts: epoch {out['epoch']}, {out['hosts_lost']} lost, "
            f"{out['hosts_joined']} joined, {out['reshards']} reshards, "
            f"{seb.stale_epoch_trajs} stale-epoch trajectories dropped"
        )


if __name__ == "__main__":
    main()
