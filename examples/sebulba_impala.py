"""Sebulba running IMPALA/V-trace on host (CPU) environments — paper Fig. 3.

Run with several placeholder devices to exercise the actor/learner core
split (on a real TPU host the 8 cores appear automatically):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sebulba_impala.py --frames 50000
"""

import argparse

import jax

from repro import optim
from repro.agents.impala import ConvActorCritic
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import BatchedHostEnv, HostPong, Pong


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=50_000)
    ap.add_argument("--actor-cores", type=int, default=2)
    ap.add_argument("--actor-batch", type=int, default=32)
    ap.add_argument("--trajectory", type=int, default=20)
    ap.add_argument("--device-envs", action="store_true",
                    help="step the pure-JAX Pong twin on device (fused "
                         "env+act actor step) instead of the host env pool")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist param_version-stamped checkpoints here "
                         "(the runner owns persistence — see repro.api)")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint every N learner updates")
    ap.add_argument("--restore-from", default=None,
                    help="warm-start params from a checkpoint file or dir")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    actor_cores = min(args.actor_cores, max(1, n_dev - 1)) if n_dev > 1 else 1
    learners = max(n_dev - actor_cores, 1)
    # the batch shards across learner cores; round up to the next multiple
    # (a 6-learner split would otherwise reject the power-of-two default)
    actor_batch = -(-args.actor_batch // learners) * learners
    if actor_batch != args.actor_batch:
        print(f"actor batch {args.actor_batch} -> {actor_batch} "
              f"(multiple of {learners} learners)")
    print(f"devices: {n_dev} -> {actor_cores} actor / "
          f"{learners} learner cores")

    net = ConvActorCritic(HostPong.num_actions, channels=(16, 32), blocks=1)
    env_kwargs = (
        {"device_env": Pong}
        if args.device_envs
        else {
            "env_factory": lambda seed: HostPong(seed=seed),
            "make_batched_env": lambda f, n: BatchedHostEnv(f, n),
        }
    )
    seb = Sebulba(
        network=net,
        optimizer=optim.rmsprop(3e-4, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=actor_cores,
            threads_per_actor_core=2,
            actor_batch_size=actor_batch,
            trajectory_length=args.trajectory,
        ),
        **env_kwargs,
    )
    out = seb.fit(jax.random.key(0), total_frames=args.frames, log_every=25,
                  checkpoint_dir=args.checkpoint_dir,
                  checkpoint_every=args.checkpoint_every,
                  restore_from=args.restore_from)
    print(
        f"\n{out['frames']:,} frames in {out['seconds']:.1f}s "
        f"-> {out['fps']:,.0f} FPS, {out['updates']} updates, "
        f"mean return {out['mean_return']:.2f}, "
        f"{out['checkpoints_saved']} checkpoints"
    )


if __name__ == "__main__":
    main()
