"""Sebulba running MuZero with pure-JAX MCTS on the actor cores (paper
§Sebulba / Fig. 4c), including the batch-splitting trick that decouples
acting batch size from learning batch size (learner_microbatches).

Protocol notes (repro.api): ``MuZeroAgent`` declares
``AgentSpec(extras_keys=("visit_probs",))`` — the per-step MCTS visit
distributions ride the device trajectory ring as a NAMED extra
(``Trajectory.extras["visit_probs"]``), validated against the declaration
when the ring is allocated.  That named channel is exactly what the
roadmap's MuZero-reanalyze needs to read back out of replay (sample a
trajectory, re-run MCTS under fresh params, overwrite ``visit_probs``) —
a reanalyze agent would declare ``AgentSpec(replay=True,
extras_keys=("visit_probs",))`` and plug into Sebulba replay mode
unchanged; this example is the on-policy template for it.

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sebulba_muzero.py --frames 10000
"""

import argparse

import jax

from repro import optim
from repro.agents.muzero import MuZeroAgent, MuZeroConfig
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import BatchedHostEnv, HostPong


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=10_000)
    ap.add_argument("--simulations", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=2)
    ap.add_argument("--actor-batch", type=int, default=16)
    ap.add_argument("--trajectory", type=int, default=12)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist param_version-stamped checkpoints here")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint every N learner updates")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    actor_cores = min(2, n_dev - 1) if n_dev > 1 else 1
    learners = max(n_dev - actor_cores, 1)
    # the batch shards across learner cores AND splits into microbatches
    mult = learners * args.microbatches
    actor_batch = -(-args.actor_batch // mult) * mult
    if actor_batch != args.actor_batch:
        print(f"actor batch {args.actor_batch} -> {actor_batch} "
              f"(multiple of {learners} learners x {args.microbatches} "
              "microbatches)")

    agent = MuZeroAgent(
        HostPong.num_actions,
        MuZeroConfig(num_simulations=args.simulations, max_depth=6,
                     unroll_steps=4),
    )
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        optimizer=optim.adam(1e-3, clip_norm=1.0),
        agent=agent,
        config=SebulbaConfig(
            num_actor_cores=actor_cores,
            actor_batch_size=actor_batch,
            trajectory_length=args.trajectory,
            learner_microbatches=args.microbatches,  # the paper's trick
        ),
    )
    out = seb.fit(jax.random.key(0), total_frames=args.frames, log_every=10,
                  checkpoint_dir=args.checkpoint_dir,
                  checkpoint_every=args.checkpoint_every)
    print(
        f"\n{out['frames']:,} frames, {out['fps']:,.0f} FPS "
        f"(search-based acting), mean return {out['mean_return']:.2f}"
    )


if __name__ == "__main__":
    main()
