"""Sebulba running MuZero with pure-JAX MCTS on the actor cores (paper
§Sebulba / Fig. 4c), including the batch-splitting trick that decouples
acting batch size from learning batch size (learner_microbatches).

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sebulba_muzero.py --frames 10000
"""

import argparse

import jax

from repro import optim
from repro.agents.muzero import MuZeroAgent, MuZeroConfig
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import BatchedHostEnv, HostPong


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=10_000)
    ap.add_argument("--simulations", type=int, default=16)
    ap.add_argument("--microbatches", type=int, default=2)
    args = ap.parse_args()

    agent = MuZeroAgent(
        HostPong.num_actions,
        MuZeroConfig(num_simulations=args.simulations, max_depth=6,
                     unroll_steps=4),
    )
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        optimizer=optim.adam(1e-3, clip_norm=1.0),
        agent=agent,
        config=SebulbaConfig(
            num_actor_cores=2 if len(jax.devices()) > 1 else 1,
            actor_batch_size=16,
            trajectory_length=12,
            learner_microbatches=args.microbatches,  # the paper's trick
        ),
    )
    out = seb.run(jax.random.key(0), (16, 16, 1), total_frames=args.frames,
                  log_every=10)
    print(
        f"\n{out['frames']:,} frames, {out['fps']:,.0f} FPS "
        f"(search-based acting), mean return {out['mean_return']:.2f}"
    )


if __name__ == "__main__":
    main()
