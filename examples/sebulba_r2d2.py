"""R2D2 on Sebulba: recurrent agent, stored state, burn-in, prioritized
sequence replay (Kapturowski et al. 2019) — end to end.

This *is* R2D2 now, not just its dataflow: the agent is a recurrent
actor-critic (IMPALA conv torso -> RG-LRU temporal core, the ``rglru_scan``
kernel wrapper — stored-state training scans take the log-depth
associative scan with its linear-memory custom VJP on every backend, and
acting is a single-step recurrence; the zero-state-only Pallas TPU kernel
serves griffin's prefill, not this agent.  ``--core lax`` swaps in the
sequential pure-lax reference).  Actor cores thread the recurrent state
through the fused donated act-step (reset on episode boundaries via the
discount channel) and record the state entering each trajectory slice; the
slice replays from that **stored state**, and a ``--burn-in`` prefix is
unrolled gradient-free to refresh it before the V-trace loss.

Stored state vs zero state vs burn-in (the Kapturowski et al. ablation):

  * **zero-state** replay (their baseline) zeroes the carry at the start of
    every replayed sequence — cheap, but the early steps of every sequence
    train against a state distribution the actor never produces;
  * **stored state** replays from the actor's recorded carry (what this
    example always does) — right distribution, but *stale*: it was computed
    under the params of record time, not the params doing the update;
  * **burn-in** (``--burn-in K``) repairs the staleness by re-unrolling the
    first K steps with CURRENT params from the stored state, gradient-free,
    so only the refreshed suffix trains.  Their best results combine
    stored state + burn-in, which is the configuration here.

The learner side is unchanged Podracer machinery: trajectory shards stream
device-to-device into the replay ring sharded over the learner mesh, every
update trains on a mixed online+replay batch inside one fused donated jit
(insert -> sample -> burn-in -> weighted V-trace -> TD-priority
write-back), and V-trace absorbs the policy lag.  See ARCHITECTURE.md for
the full dataflow.

Run with placeholder devices to exercise the full actor/learner/replay
split (real TPU hosts expose their 8 cores automatically):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sebulba_r2d2.py --frames 50000
"""

import argparse

import jax

from repro import optim
from repro.agents.recurrent import (
    RecurrentConvActorCritic,
    RecurrentReplayImpalaAgent,
)
from repro.configs.base import ReplayConfig
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import BatchedHostEnv, HostPong


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=50_000)
    ap.add_argument("--actor-cores", type=int, default=2)
    ap.add_argument("--actor-batch", type=int, default=24)
    ap.add_argument("--trajectory", type=int, default=20)
    ap.add_argument("--burn-in", type=int, default=5,
                    help="gradient-free unroll steps refreshing the stored "
                         "state before the V-trace loss (0 disables; must "
                         "be < --trajectory)")
    ap.add_argument("--core", choices=["rglru", "lax"], default="rglru",
                    help="temporal core: the rglru_scan kernel wrapper "
                         "(log-depth associative scan + linear-memory "
                         "custom VJP for these stored-state scans) or the "
                         "sequential pure-lax reference")
    ap.add_argument("--rnn-width", type=int, default=128,
                    help="RG-LRU state width (the stored-state bytes per "
                         "sequence scale with this)")
    ap.add_argument("--capacity", type=int, default=2048,
                    help="replay slots (global, sharded over learner cores)")
    ap.add_argument("--replay-batch", type=int, default=24,
                    help="replay trajectories sampled per learner update")
    ap.add_argument("--min-size", type=int, default=96,
                    help="warmup inserts before learning starts")
    ap.add_argument("--uniform", action="store_true",
                    help="uniform instead of prioritized sampling")
    ap.add_argument("--anneal-updates", type=int, default=0,
                    help="linearly anneal the PER importance exponent "
                         "(beta) to 1.0 over this many learner updates "
                         "(0 keeps it fixed)")
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist param_version-stamped checkpoints here")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint every N learner updates")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    actor_cores = min(args.actor_cores, max(1, n_dev - 1)) if n_dev > 1 else 1
    learners = max(n_dev - actor_cores, 1)

    # Sebulba shards the batch and the replay ring over the learner cores,
    # so round the requested sizes up to the nearest multiple of that count
    # (the CLI defaults assume powers of two; a 6-learner split would
    # otherwise be rejected).
    def _round_up(x: int, m: int) -> int:
        return -(-x // m) * m

    actor_batch = _round_up(args.actor_batch, learners)
    capacity = _round_up(args.capacity, learners)
    replay_batch = _round_up(args.replay_batch, learners)
    if (actor_batch, capacity, replay_batch) != (
            args.actor_batch, args.capacity, args.replay_batch):
        print(f"rounded to learner multiple of {learners}: "
              f"actor_batch={actor_batch} capacity={capacity} "
              f"replay_batch={replay_batch}")
    print(f"devices: {n_dev} -> {actor_cores} actor / {learners} learner "
          f"cores, replay ring {capacity} slots "
          f"({capacity // learners}/core), burn-in {args.burn_in}, "
          f"core {args.core}")

    net = RecurrentConvActorCritic(
        HostPong.num_actions, channels=(16, 32), blocks=1,
        rnn_width=args.rnn_width, core=args.core,
    )
    config = SebulbaConfig(
        num_actor_cores=actor_cores,
        threads_per_actor_core=2,
        actor_batch_size=actor_batch,
        trajectory_length=args.trajectory,
        burn_in=args.burn_in,
        replay=ReplayConfig(
            capacity=capacity,
            sample_batch_size=replay_batch,
            min_size=min(args.min_size, capacity),
            prioritized=not args.uniform,
            importance_anneal_updates=args.anneal_updates,
        ),
    )
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        optimizer=optim.rmsprop(3e-4, clip_norm=1.0),
        config=config,
        agent=RecurrentReplayImpalaAgent(net, config),
    )
    out = seb.fit(jax.random.key(0), total_frames=args.frames, log_every=25,
                  checkpoint_dir=args.checkpoint_dir,
                  checkpoint_every=args.checkpoint_every)
    print(
        f"\n{out['frames']:,} frames in {out['seconds']:.1f}s "
        f"-> {out['fps']:,.0f} FPS, {out['updates']} updates, "
        f"replay size {out['replay_size']}, "
        f"mean return {out['mean_return']:.2f}"
    )


if __name__ == "__main__":
    main()
