"""Off-policy Sebulba: R2D2-style replay IMPALA on host environments.

"R2D2-style" refers to the *dataflow* (prioritized sequence replay feeding
the learner, Kapturowski et al. 2019) — the agent here is a feed-forward
replay IMPALA, not R2D2 itself; the recurrent network, stored LSTM state,
and burn-in are still-open ROADMAP work on top of this subsystem.

The paper notes Sebulba hosts replay-based agents (MuZero) as well as the
on-policy ones; this example runs that dataflow end to end.  Actor cores
stream trajectory shards into a device-resident prioritized replay ring
sharded across the learner cores; every learner update trains on a mixed
batch — the fresh online shard plus ``sample_batch_size`` replayed
trajectories — with V-trace correcting the policy lag and PER importance
weights correcting the sampling bias.

Run with placeholder devices to exercise the full actor/learner/replay
split (real TPU hosts expose their 8 cores automatically):

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/sebulba_r2d2.py --frames 50000
"""

import argparse

import jax

from repro import optim
from repro.agents.impala import ConvActorCritic
from repro.configs.base import ReplayConfig
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import BatchedHostEnv, HostPong


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=50_000)
    ap.add_argument("--actor-cores", type=int, default=2)
    ap.add_argument("--actor-batch", type=int, default=24)
    ap.add_argument("--trajectory", type=int, default=20)
    ap.add_argument("--capacity", type=int, default=2048,
                    help="replay slots (global, sharded over learner cores)")
    ap.add_argument("--replay-batch", type=int, default=24,
                    help="replay trajectories sampled per learner update")
    ap.add_argument("--min-size", type=int, default=96,
                    help="warmup inserts before learning starts")
    ap.add_argument("--uniform", action="store_true",
                    help="uniform instead of prioritized sampling")
    ap.add_argument("--anneal-updates", type=int, default=0,
                    help="linearly anneal the PER importance exponent "
                         "(beta) to 1.0 over this many learner updates "
                         "(0 keeps it fixed)")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    actor_cores = min(args.actor_cores, max(1, n_dev - 1)) if n_dev > 1 else 1
    learners = max(n_dev - actor_cores, 1)

    # Sebulba shards the batch and the replay ring over the learner cores,
    # so round the requested sizes up to the nearest multiple of that count
    # (the CLI defaults assume powers of two; a 6-learner split would
    # otherwise be rejected).
    def _round_up(x: int, m: int) -> int:
        return -(-x // m) * m

    actor_batch = _round_up(args.actor_batch, learners)
    capacity = _round_up(args.capacity, learners)
    replay_batch = _round_up(args.replay_batch, learners)
    if (actor_batch, capacity, replay_batch) != (
            args.actor_batch, args.capacity, args.replay_batch):
        print(f"rounded to learner multiple of {learners}: "
              f"actor_batch={actor_batch} capacity={capacity} "
              f"replay_batch={replay_batch}")
    print(f"devices: {n_dev} -> {actor_cores} actor / {learners} learner "
          f"cores, replay ring {capacity} slots "
          f"({capacity // learners}/core)")

    net = ConvActorCritic(HostPong.num_actions, channels=(16, 32), blocks=1)
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net,
        optimizer=optim.rmsprop(3e-4, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=actor_cores,
            threads_per_actor_core=2,
            actor_batch_size=actor_batch,
            trajectory_length=args.trajectory,
            replay=ReplayConfig(
                capacity=capacity,
                sample_batch_size=replay_batch,
                min_size=min(args.min_size, capacity),
                prioritized=not args.uniform,
                importance_anneal_updates=args.anneal_updates,
            ),
        ),
    )
    out = seb.run(jax.random.key(0), (16, 16, 1), total_frames=args.frames,
                  log_every=25)
    print(
        f"\n{out['frames']:,} frames in {out['seconds']:.1f}s "
        f"-> {out['fps']:,.0f} FPS, {out['updates']} updates, "
        f"replay size {out['replay_size']}, "
        f"mean return {out['mean_return']:.2f}"
    )


if __name__ == "__main__":
    main()
