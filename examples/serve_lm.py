"""Serve a small model with continuous batching: the PR 10 serving stack
(paged KV cache + chunked prefill + request scheduler) driven end to end
through the public API, with the static-batch path alongside for
comparison.

Prefill goes through the fused ``Model.prefill_step`` forward pass — one
``(B, C)`` dispatch per chunk — not the old token-by-token teacher-forced
decode loop (the prefill-vs-decode parity pin in tests/test_models.py
covers their equivalence).

    PYTHONPATH=src python examples/serve_lm.py --batch 8 --prompt-len 64 --gen 64
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_reduced_config
from repro.launch.steps import make_serve_step
from repro.models import make_model
from repro.serve import Request, ServeConfig, ServeEngine


def static_batch(model, params, prompts, gen: int):
    """The pre-engine baseline: fused prefill of the whole (equal-length)
    prompt batch, then lockstep greedy decode — the batch moves at the
    pace of its slowest request."""
    cfg = model.cfg
    B, L = prompts.shape
    total = L + gen
    cache, _ = model.init_cache(B, total)
    prefill = jax.jit(model.prefill_step)
    serve = jax.jit(make_serve_step(model))

    t0 = time.time()
    logits, _, cache = prefill(
        params, cache, prompts, jnp.zeros((B,), jnp.int32)
    )
    logits[:, -1].block_until_ready()
    prefill_s = time.time() - t0

    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for t in range(L, total - 1):
        tok, cache = serve(params, cache, tok, jnp.int32(t))
        generated.append(tok)
    out = jnp.concatenate(generated, axis=1)
    out.block_until_ready()
    decode_s = time.time() - t0
    return out, prefill_s, decode_s


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B = args.batch
    total = args.prompt_len + args.gen
    print(f"serving reduced {cfg.name}: batch {B}, cache {total} tokens")

    prompts = jax.random.randint(
        jax.random.key(1), (B, args.prompt_len), 0, cfg.vocab_size,
        dtype=jnp.int32,
    )

    # --- static batching: fused prefill + lockstep decode ---------------
    out, prefill_s, decode_s = static_batch(model, params, prompts, args.gen)
    print(f"static prefill (fused): {B * args.prompt_len / prefill_s:,.0f} tok/s")
    print(f"static decode:          {B * args.gen / decode_s:,.0f} tok/s")
    print(f"sample continuation (request 0): {out[0, :16].tolist()}")

    # --- continuous batching: paged KV + chunked prefill ----------------
    bs = 16
    scfg = ServeConfig(
        batch_rows=B, prefill_chunk=32, token_budget=B + 32,
        block_size=bs, num_blocks=1 + B * (total // bs + 1),
        max_seq=((total + bs - 1) // bs) * bs,
        temperature=args.temperature, seed=0,
    )
    engine = ServeEngine(model, params, scfg, paged=True)
    reqs = [
        Request(rid=i + 1, prompt=tuple(int(t) for t in prompts[i]),
                max_new_tokens=args.gen)
        for i in range(B)
    ]
    res = engine.run(reqs)
    print(f"continuous (paged KV):  {res['tokens_per_s']:,.0f} tok/s processed, "
          f"TTFT p50 {res['ttft_p50'] * 1e3:.1f} ms, "
          f"occupancy peak {res['cache_occupancy_peak']:.0%}")
    print(f"sample continuation (request 1): {res['outputs'][1][:16]}")


if __name__ == "__main__":
    main()
