"""Serve a small model with batched requests: the Sebulba-actor decode path
(prefill -> KV cache -> batched single-token serve_step loop) driven by the
public API — the inference-side end-to-end driver.

    PYTHONPATH=src python examples/serve_lm.py --batch 8 --prompt-len 64 --gen 64
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_reduced_config
from repro.launch.steps import make_serve_step
from repro.models import make_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=64)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B = args.batch
    total = args.prompt_len + args.gen
    print(f"serving reduced {cfg.name}: batch {B}, cache {total} tokens")

    prompts = jax.random.randint(
        jax.random.key(1), (B, args.prompt_len), 0, cfg.vocab_size
    )
    cache, _ = model.init_cache(B, total)

    # prefill: teacher-force the prompt through decode steps (simple serving
    # loop; a production prefill would use the fused forward path)
    step = jax.jit(model.decode_step)
    t0 = time.time()
    for t in range(args.prompt_len):
        logits, _, cache = step(params, cache, prompts[:, t : t + 1],
                                jnp.int32(t))
    prefill_s = time.time() - t0

    serve = jax.jit(make_serve_step(model))
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for t in range(args.prompt_len, total):
        tok, cache = serve(params, cache, tok, jnp.int32(t))
        generated.append(tok)
    decode_s = time.time() - t0

    out = jnp.concatenate(generated, axis=1)
    print(f"prefill: {B * args.prompt_len / prefill_s:,.0f} tok/s")
    print(f"decode:  {B * args.gen / decode_s:,.0f} tok/s")
    print(f"sample continuation (request 0): {out[0, :16].tolist()}")


if __name__ == "__main__":
    main()
