"""End-to-end driver: an LM policy as a first-class Podracer agent.

The transformer *is* the policy: ``LMPolicyAgent.act`` generates one token
per env step through ``model.decode_step`` (the flash_decode hot loop),
threading the KV cache + position counter as Sebulba's declared carry, on
the pure-JAX ``TokenEnv`` copy/reverse task.  The learner re-scores stale
generations with one teacher-forced forward and optimizes the V-trace-
corrected LM objective (CE + importance-weighted actor-critic).  All of it
flows through the UNCHANGED Sebulba core — ring, drain, shard, publish —
and reports the unified ``repro.api.RESULT_KEYS`` schema.

Default config is a ~25M-parameter qwen2-family model sized for this CPU
container; ``--preset 100m`` scales to ~100M params (the assignment's
end-to-end target — run it on real hardware or be patient); ``--preset
tiny`` is the CI smoke size.

    XLA_FLAGS=--xla_force_host_platform_device_count=2 \
        PYTHONPATH=src python examples/train_lm_rl.py --preset 25m
"""

import argparse
import dataclasses

import jax

from repro import optim
from repro.agents.lm_policy import LMPolicyAgent, LMReplayPolicyAgent
from repro.checkpoint import save
from repro.configs.base import ReplayConfig, get_config
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import TokenEnv
from repro.launch.steps import TrainHParams

PRESETS = {
    # CI smoke size: compiles in seconds
    "tiny": dict(num_layers=2, d_model=64, num_heads=2, num_kv_heads=1,
                 head_dim=32, d_ff=128, vocab_size=128),
    # ~25M params: CPU-friendly
    "25m": dict(num_layers=4, d_model=384, num_heads=6, num_kv_heads=2,
                head_dim=64, d_ff=1536, vocab_size=8192),
    # ~100M params: the assignment's end-to-end scale
    "100m": dict(num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=16384),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=sorted(PRESETS))
    ap.add_argument("--frames", type=int, default=4096)
    ap.add_argument("--task", default="copy", choices=["copy", "reverse"])
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--data-vocab", type=int, default=16,
                    help="distinct prompt tokens (small -> learnable fast)")
    ap.add_argument("--actor-cores", type=int, default=1)
    ap.add_argument("--actor-batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--replay", action="store_true",
                    help="train off-policy with prioritized replay "
                         "(the declared replay capability)")
    ap.add_argument("--ckpt", default="experiments/train_lm_rl.npz")
    args = ap.parse_args()

    n_dev = len(jax.devices())
    actor_cores = min(args.actor_cores, max(1, n_dev - 1)) if n_dev > 1 else 1
    learners = max(n_dev - actor_cores, 1)
    actor_batch = -(-args.actor_batch // learners) * learners
    if actor_batch != args.actor_batch:
        print(f"actor batch {args.actor_batch} -> {actor_batch} "
              f"(multiple of {learners} learners)")
    print(f"devices: {n_dev} -> {actor_cores} actor / "
          f"{learners} learner cores")

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"), **PRESETS[args.preset], qkv_bias=True,
        remat="none",
    )
    env = TokenEnv(vocab_size=cfg.vocab_size, prompt_len=args.prompt_len,
                   task=args.task, data_vocab=args.data_vocab)
    agent = (LMReplayPolicyAgent if args.replay else LMPolicyAgent)(
        cfg, max_seq=env.episode_len,
        hparams=TrainHParams(rl_weight=0.1, entropy_cost=0.003),
    )
    n_params = sum(x.size for x in jax.tree.leaves(
        agent.init(jax.random.key(0), env.obs_shape)))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model}), "
          f"{args.task} task, episode {env.episode_len} tokens")

    seb = Sebulba(
        optimizer=optim.adam(args.lr, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=actor_cores,
            threads_per_actor_core=2,
            actor_batch_size=actor_batch,
            trajectory_length=env.episode_len,
            replay=ReplayConfig(capacity=256, sample_batch_size=actor_batch,
                                min_size=4 * actor_batch, prioritized=True)
            if args.replay else None,
        ),
        agent=agent,
        device_env=env,
    )
    out = seb.fit(jax.random.key(0), total_frames=args.frames, log_every=25)
    m = out["metrics"]
    print(
        f"\n{out['frames']:,} frames in {out['seconds']:.1f}s "
        f"-> {out['fps']:,.0f} FPS, {out['updates']} updates\n"
        f"loss {float(m['loss']):.4f}  ce {float(m['ce']):.4f}  "
        f"rl {float(m['rl']):+.4f}  entropy {float(m['entropy']):.3f}  "
        f"mean return {out['mean_return']:.2f} "
        f"(max {env.episode_len // 2})"
    )
    if args.replay:
        print(f"replay: {out['replay_size']} trajectories held")
    save(args.ckpt, out["params"])
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
