"""End-to-end driver: train a transformer policy with the Sebulba-learner
objective (LM cross-entropy + V-trace actor-critic) on synthetic token
trajectories, with checkpointing and a cosine schedule.

Default config is a ~25M-parameter qwen2-family model sized for this CPU
container; ``--preset 100m`` scales to ~100M params (the assignment's
end-to-end target — run it on real hardware or be patient).

    PYTHONPATH=src python examples/train_lm_rl.py --steps 200
"""

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro import optim
from repro.checkpoint import save
from repro.configs.base import get_config
from repro.launch.specs import make_batch
from repro.launch.steps import TrainHParams, make_train_step
from repro.models import make_model

PRESETS = {
    # ~25M params: CPU-friendly
    "25m": dict(num_layers=4, d_model=384, num_heads=6, num_kv_heads=2,
                head_dim=64, d_ff=1536, vocab_size=8192),
    # ~100M params: the assignment's end-to-end scale
    "100m": dict(num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
                 head_dim=64, d_ff=3072, vocab_size=16384),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="25m", choices=sorted(PRESETS))
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt", default="experiments/train_lm_rl.npz")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"), **PRESETS[args.preset], qkv_bias=True,
        remat="none",
    )
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"model: {n_params / 1e6:.1f}M params "
          f"({cfg.num_layers}L d={cfg.d_model})")

    opt = optim.adam(
        optim.warmup_cosine(args.lr, warmup=20, total_steps=args.steps),
        clip_norm=1.0,
    )
    step = jax.jit(make_train_step(model, opt, TrainHParams(rl_weight=0.1)))
    opt_state = opt.init(params)

    # synthetic copy-task-ish data: structured tokens so CE can fall
    def data_batch(i):
        rng = jax.random.key(1000 + i % 37)
        batch = make_batch(cfg, args.batch, args.seq, rng=rng)
        t = jnp.arange(args.seq) % 97
        batch["tokens"] = (batch["tokens"] % 13) * 97 + t[None, :]
        batch["tokens"] = batch["tokens"] % cfg.vocab_size
        return batch

    t0 = time.time()
    for i in range(args.steps):
        params, opt_state, metrics = step(params, opt_state, data_batch(i))
        if i % 20 == 0 or i == args.steps - 1:
            toks = args.batch * args.seq * (i + 1)
            print(
                f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                f"ce {float(metrics['ce']):.4f}  rl {float(metrics['rl']):+.4f}  "
                f"tok/s {toks / (time.time() - t0):,.0f}"
            )
    save(args.ckpt, params)
    print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
