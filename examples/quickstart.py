"""Quickstart: Anakin on Catch — the paper's Colab demo, reproduced.

The whole agent-environment loop (env stepping, action selection, A2C
update) compiles into ONE XLA program, replicated over every available
device with explicit pmean gradient averaging (paper Fig. 2).

    PYTHONPATH=src python examples/quickstart.py
"""

import time

import jax

from repro import optim
from repro.agents.actor_critic import MLPActorCritic
from repro.core.anakin import Anakin, AnakinConfig
from repro.envs import Catch


def main() -> None:
    env = Catch()
    net = MLPActorCritic(env.num_actions, hidden=(64, 64))
    anakin = Anakin(
        env,
        net,
        optim.adam(3e-3, clip_norm=1.0),
        AnakinConfig(
            unroll_length=10,  # N env steps per update
            batch_per_device=64,  # vmap width (fill the core)
            iterations_per_call=50,  # updates fused into one XLA call
            mode="shard_map",  # paper-faithful explicit pmean
        ),
    )
    print(f"devices: {jax.device_count()}  "
          f"global env batch: {anakin.global_batch}")

    state = anakin.init_state(jax.random.key(0))
    t0 = time.time()
    for call in range(10):
        state, metrics = anakin.run(state)
        fps = anakin.steps_per_call * (call + 1) / (time.time() - t0)
        print(
            f"call {call:2d}  reward/step {float(metrics['reward']):+.3f}  "
            f"entropy {float(metrics['entropy']):.3f}  fps {fps:,.0f}"
        )
    reward = float(metrics["reward"])
    print(f"\nfinal reward/step: {reward:+.3f} (optimal = +{1 / 9:.3f})")
    assert reward > 0.08, "did not learn Catch"


if __name__ == "__main__":
    main()
