"""Quickstart: Anakin on Catch — the paper's Colab demo, reproduced.

The whole agent-environment loop (env stepping, action selection, A2C
update) compiles into ONE XLA program, replicated over every available
device with explicit pmean gradient averaging (paper Fig. 2), driven
through the unified Podracer runner surface (``repro.api``): one ``fit``
call, one result schema, optional ``param_version``-stamped checkpoints.

    PYTHONPATH=src python examples/quickstart.py
"""

import argparse

import jax

from repro import optim
from repro.agents.actor_critic import MLPActorCritic
from repro.core.anakin import Anakin, AnakinConfig
from repro.envs import Catch

FULL_FRAMES = 320_000  # 10 compiled calls at the default config


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=FULL_FRAMES)
    ap.add_argument("--checkpoint-dir", default=None,
                    help="persist param_version-stamped checkpoints here")
    ap.add_argument("--checkpoint-every", type=int, default=0,
                    help="checkpoint every N learner updates (0 = only the "
                         "final save when --checkpoint-dir is set)")
    args = ap.parse_args()

    env = Catch()
    net = MLPActorCritic(env.num_actions, hidden=(64, 64))
    anakin = Anakin(
        env,
        net,
        optim.adam(3e-3, clip_norm=1.0),
        AnakinConfig(
            unroll_length=10,  # N env steps per update
            batch_per_device=64,  # vmap width (fill the core)
            iterations_per_call=50,  # updates fused into one XLA call
            mode="shard_map",  # paper-faithful explicit pmean
        ),
    )
    print(f"devices: {jax.device_count()}  "
          f"global env batch: {anakin.global_batch}")

    out = anakin.fit(
        jax.random.key(0), total_frames=args.frames, log_every=50,
        checkpoint_dir=args.checkpoint_dir,
        checkpoint_every=args.checkpoint_every,
    )
    reward = float(out["metrics"].get("reward", float("nan")))
    print(
        f"\n{out['frames']:,} frames in {out['seconds']:.1f}s "
        f"-> {out['fps']:,.0f} FPS, {out['updates']} updates, "
        f"final reward/step {reward:+.3f} (optimal = +{1 / 9:.3f})"
    )
    if args.frames >= FULL_FRAMES:  # smoke runs train too little to judge
        assert reward > 0.08, "did not learn Catch"


if __name__ == "__main__":
    main()
